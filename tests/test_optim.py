"""Optimizer + compression substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
import hypothesis.strategies as st

from repro.configs.base import TrainConfig
from repro.optim import compression
from repro.optim.optimizer import (
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_state,
    lr_schedule,
)


def test_adamw_converges_on_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = init_state(params, tcfg)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(params, g, state, tcfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_warmup_then_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10)
    lrs = [float(lr_schedule(tcfg, jnp.int32(s))) for s in (1, 5, 10, 40, 90)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rising
    assert lrs[2] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[3] == pytest.approx(1e-3 / 2, rel=1e-5)   # 1/sqrt(4x)
    assert lrs[4] == pytest.approx(1e-3 / 3, rel=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_moment_dtype_respected():
    tcfg = TrainConfig(moment_dtype=jnp.bfloat16)
    st_ = init_state({"w": jnp.zeros((4,), jnp.bfloat16)}, tcfg)
    assert st_.mu["w"].dtype == jnp.bfloat16


# --------------------------- compression ------------------------------------

def test_bf16_codec_is_near_lossless_for_bf16_scale():
    g = {"w": jnp.asarray([0.125, -2.0, 3.5])}
    dec, _ = compression.compress(g, "bf16")
    np.testing.assert_allclose(dec["w"], g["w"], rtol=1e-2)


def test_int8_ef_error_feedback_property():
    """Cumulative compressed sum tracks cumulative true sum with O(1)
    error (not O(steps)) — the EF guarantee."""
    rng = np.random.default_rng(0)
    ef = compression.init_ef({"w": jnp.zeros(64)})
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    for _ in range(100):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)}
        dec, ef = compression.compress(g, "int8_ef", ef)
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(dec["w"])
    # residual bound: final error equals the EF buffer, not accumulated drift
    resid = np.abs(true_sum - sent_sum).max()
    assert resid < 0.01, f"EF drift too large: {resid}"


def test_int8_ef_requires_state():
    with pytest.raises(AssertionError):
        compression.compress({"w": jnp.zeros(4)}, "int8_ef", None)


@given(scale=st.floats(1e-6, 1e3))
@settings(max_examples=30, deadline=None)
def test_int8_quantize_bounded_error(scale):
    x = jnp.asarray(np.linspace(-scale, scale, 255), jnp.float32)
    q, s = compression._quantize_int8(x)
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-9  # half-ULP of the int8 grid
