"""Continuous-batching scheduler: correctness + executable-cache reuse.

The strongest invariant: any traffic mix (mixed prompt lengths, mixed
generation lengths, more requests than slots, bucketed admission with
the single-step correction) produces, per request, EXACTLY the tokens a
solo ``Server.generate`` run produces — continuous batching is a
scheduling choice, never a numerics choice.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.scheduler import ContinuousBatchingServer, probe_batch_axes
from repro.launch.serve import Server
from repro.models import layers as L
from repro.models.registry import get_model

ARCHS = ["nemotron-4-15b", "deepseek-v3-671b"]  # GQA and MLA+MoE caches


def _setup(arch):
    import dataclasses

    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # pad tokens in a bucketed prefill legitimately compete for MoE
        # expert capacity (same effect test_decode_consistency isolates);
        # a no-drop capacity factor keeps the test about the scheduler
        # machinery, not capacity-drop semantics.
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, api, params


def _traffic(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(2, 14)).astype(
            np.int32), int(rng.randint(1, 9)))
        for _ in range(n)
    ]


@pytest.mark.parametrize("arch", ARCHS)
def test_continuous_matches_solo_decode(arch):
    cfg, api, params = _setup(arch)
    sched = ContinuousBatchingServer(cfg, params, num_slots=3, max_len=48,
                                     buckets=(8, 16), segment=4)
    solo = Server(cfg, params, max_len=48)
    reqs = _traffic(cfg, 7, seed=3)
    rids = [sched.submit(p, g) for p, g in reqs]
    done = sched.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        prompt, gen = reqs[r.rid]
        assert r.generated == gen
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens,
            err_msg=f"{arch} rid {r.rid}: continuous != solo decode",
        )


def test_slots_are_reused_and_cache_is_persistent():
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4)
    n_leaves = len(jax.tree.leaves(sched.cache))
    assert n_leaves > 0
    reqs = _traffic(cfg, 5, seed=4)
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 5  # 5 requests drained through 2 slots
    assert sched.stats["admitted"] == 5
    assert all(s.free for s in sched.slots)
    # ONE slot cache for the server's whole lifetime: still num_slots
    # rows on every leaf's probed batch axis (never reallocated per
    # request batch like the PR-2 Server did)
    assert len(jax.tree.leaves(sched.cache)) == n_leaves
    for leaf, ax in zip(jax.tree.leaves(sched.cache),
                        jax.tree.leaves(sched.axes)):
        assert leaf.shape[ax] == 2


def test_repeat_traffic_never_recompiles():
    """The executable cache is keyed by (kind, bucket/shape, plan):
    a second wave of same-bucket traffic must be all cache hits."""
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8, 16), segment=4)
    wave = _traffic(cfg, 4, seed=5)
    for p, g in wave:
        sched.submit(p, g)
    sched.run()
    compiles_after_wave1 = sched.stats["compiles"]
    keys = sched.executable_cache_keys()
    assert compiles_after_wave1 == len(keys)
    assert any(k[0] == "prefill" for k in keys)
    assert any(k[0] == "segment" for k in keys)
    for p, g in wave:
        sched.submit(p, g)
    sched.run()
    assert sched.stats["compiles"] == compiles_after_wave1
    assert sched.executable_cache_keys() == keys


def test_admission_into_freed_slots_between_segments():
    """More requests than slots: later requests must be admitted only
    when a slot frees, and every request still drains correctly."""
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=48,
                                     buckets=(8,), segment=3)
    reqs = _traffic(cfg, 3, seed=6)
    for p, g in reqs:
        sched.submit(p, g)
    seen: list[int] = []
    while sched.pending or any(not s.free for s in sched.slots):
        for r in sched.step():
            seen.append(r.rid)
    assert seen == [0, 1, 2]  # single slot => strict FIFO completion
    assert sched.stats["admitted"] == 3


def test_bucketing_pads_without_changing_tokens():
    """A prompt shorter than the bucket goes through padded prefill +
    the correction step; an exact-fit prompt skips padding. Both must
    match solo decode (this is the pad-correctness regression test)."""
    cfg, api, params = _setup("nemotron-4-15b")
    solo = Server(cfg, params, max_len=48)
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4)
    rng = np.random.RandomState(9)
    short = rng.randint(0, cfg.vocab_size, size=3).astype(np.int32)
    exact = rng.randint(0, cfg.vocab_size, size=9).astype(np.int32)  # 8+1
    single = rng.randint(0, cfg.vocab_size, size=1).astype(np.int32)
    for p in (short, exact, single):
        sched.submit(p, 6)
    done = sched.run()
    for r, p in zip(done, (short, exact, single)):
        ref = solo.generate(jnp.asarray(p)[None, :], 6, decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, p.size:], r.tokens)


def test_probe_batch_axes_finds_every_leaf():
    for arch in ("nemotron-4-15b", "deepseek-v3-671b", "qwen3-14b"):
        cfg, api, params = _setup(arch)
        axes = probe_batch_axes(api, cfg, L.HOST, 32)
        specs = api.cache_specs(cfg, L.HOST, 5, 32)
        for ax, spec in zip(jax.tree.leaves(axes),
                            jax.tree.leaves(specs, is_leaf=L.is_spec)):
            assert spec.shape[ax] == 5, (arch, spec.shape, ax)


def test_buckets_longer_than_max_len_are_dropped():
    """A bucket the KV cache can't hold must never be selected: a prompt
    near max_len admits through exact-fit prefill instead of a bucket-
    length slab overrunning the cache (regression: trace-time crash)."""
    cfg, api, params = _setup("nemotron-4-15b")
    solo = Server(cfg, params, max_len=50)
    sched = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=50,
                                     buckets=(16, 32, 64, 128), segment=4)
    assert sched.buckets == (16, 32)
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)
    assert sched.bucket_for(prompt.size - 1) == 39  # exact fit, <= max_len
    sched.submit(prompt, 5)
    (r,) = sched.run()
    ref = solo.generate(jnp.asarray(prompt)[None, :], 5, decode="loop")
    np.testing.assert_array_equal(np.asarray(ref.tokens)[0, 40:], r.tokens)


def test_segment_decode_is_batched_with_aligned_fast_path():
    """The tentpole probe: segment decode compiles ONE batched program
    (no per-slot vmap). Slots at unaligned positions run the 'ragged'
    per-row-position variant; a full batch at one shared position takes
    the 'aligned' scalar-position fast path — and both must produce the
    solo-decode tokens."""
    cfg, api, params = _setup("nemotron-4-15b")
    solo = Server(cfg, params, max_len=48)
    # aligned: every slot admitted together, identical prompt lengths
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4)
    rng = np.random.RandomState(13)
    same_len = [rng.randint(0, cfg.vocab_size, size=6).astype(np.int32)
                for _ in range(2)]
    for p in same_len:
        sched.submit(p, 6)
    done = sched.run()
    kinds = {k[3] for k in sched.executable_cache_keys()
             if k[0] == "segment"}
    assert kinds == {"aligned"}, kinds
    for r, p in zip(done, same_len):
        ref = solo.generate(jnp.asarray(p)[None, :], 6, decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, p.size:], r.tokens)
    # ragged: different prompt lengths put slots at unaligned positions
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4)
    mixed = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
             for n in (4, 9)]
    for p in mixed:
        sched.submit(p, 6)
    done = sched.run()
    kinds = {k[3] for k in sched.executable_cache_keys()
             if k[0] == "segment"}
    assert "ragged" in kinds, kinds
    for r, p in zip(done, mixed):
        ref = solo.generate(jnp.asarray(p)[None, :], 6, decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, p.size:], r.tokens)


def test_admission_rounds_are_batched():
    """Admission hysteresis: with a backlog, freed slots wait for
    ``admit_batch`` companions so the prefill GEMM runs batched — no
    batch-1 prefill program is ever compiled — and tokens still match
    solo decode exactly. Equal generation lengths retire slots together,
    so every round has its companions ready."""
    cfg, api, params = _setup("nemotron-4-15b")
    solo = Server(cfg, params, max_len=48)
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4, admit_batch=2)
    rng = np.random.RandomState(17)
    reqs = [(rng.randint(0, cfg.vocab_size, size=rng.randint(2, 8)).astype(
        np.int32), 5) for _ in range(6)]
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 6
    prefill_ks = {k[1] for k in sched.executable_cache_keys()
                  if k[0] == "prefill"}
    assert prefill_ks == {2}, prefill_ks
    for r in done:
        p, g = reqs[r.rid]
        ref = solo.generate(jnp.asarray(p)[None, :], g, decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, p.size:], r.tokens)


def test_admission_hysteresis_times_out_behind_long_request():
    """A freed slot held open for companions must not idle behind a
    long-running neighbour: the deferral lasts at most one (segment-
    capped) boundary, then admits whatever is free — so a short request
    admitted by timeout drains long before the long one finishes."""
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=64,
                                     buckets=(8,), segment=4, admit_batch=2)
    rng = np.random.RandomState(19)
    long_p = rng.randint(0, cfg.vocab_size, size=4).astype(np.int32)
    short_p = rng.randint(0, cfg.vocab_size, size=4).astype(np.int32)
    sched.submit(long_p, 40)    # occupies its slot for the whole test
    sched.submit(short_p, 3)    # retires fast, freeing one slot
    # backlog of >= 2 arms the hysteresis (a single pending request
    # admits eagerly — it has no companion to wait for)
    sched.submit(short_p, 3)
    sched.submit(short_p, 3)
    drained = []
    iterations = 0
    while (sched.pending or any(not s.free for s in sched.slots)) \
            and len(drained) < 3:
        drained += [r.rid for r in sched.step()]
        iterations += 1
        assert iterations < 25
    assert drained == [1, 2, 3]  # all shorts done; the long one still runs
    assert sched.stats["admit_deferrals"] >= 1
    assert any(not s.free for s in sched.slots)
    sched.run()


def test_async_drain_defers_token_sync():
    """run() enqueues the whole drain without materializing tokens;
    slot_tokens() is the explicit mid-stream sync point."""
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=48,
                                     buckets=(8,), segment=3)
    prompt = np.arange(1, 6, dtype=np.int32)
    sched.submit(prompt, 7)
    sched._advance()
    # retired-but-unmaterialized state is internal; a live slot exposes
    # its tokens only through the sync accessor
    part = sched.slot_tokens(0)
    assert part.dtype == np.int32 and part.size == sched.slots[0].generated
    (r,) = sched.run()
    np.testing.assert_array_equal(r.tokens[:part.size], part)
    assert r.generated == 7


def test_scheduler_rejects_unsupported_family_and_bad_requests():
    cfg, api, params = _setup("nemotron-4-15b")
    sched = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(np.arange(10, dtype=np.int32), 10)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(np.arange(4, dtype=np.int32), 0)
    with pytest.raises(ValueError, match="empty"):
        sched.submit(np.zeros((0,), np.int32), 4)
    audio = cfglib.get_smoke_config("whisper-medium")
    with pytest.raises(ValueError, match="families"):
        ContinuousBatchingServer(audio, {}, num_slots=1)
