"""Paged KV pool: block allocator protocol + prefix index + COW.

The allocator carries the sidebar discipline up to serving memory, so it
gets the sidebar's kind of coverage: random interleavings of the
lifecycle ops must never double-allocate a block, never drive a refcount
negative, and always raise ``KVPoolError`` (leaving state untouched) on
out-of-order transitions. Copy-on-write must isolate the writer from
every other owner of a shared block — checked against the real device
pool, not a mock.

Hypothesis-driven where available; seeded-random versions always run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch import kvpool as kvp
from repro.launch.kvpool import (
    BlockAllocator,
    BlockState,
    KVPoolError,
    PagedKVManager,
    chunk_key,
    chunk_keys,
    prefix_key,
)
from repro.models import layers as L
from repro.models.registry import get_model

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

N_BLOCKS = 8  # 7 allocatable + scratch


# ---------------------------------------------------------------------------
# Allocator lifecycle: directed cases.
# ---------------------------------------------------------------------------

def test_lifecycle_free_staged_active_cached_free():
    a = BlockAllocator(N_BLOCKS)
    bid = a.alloc()
    assert a.state(bid) is BlockState.STAGED and a.refcount(bid) == 1
    a.activate(bid)
    assert a.state(bid) is BlockState.ACTIVE
    a.register(b"key", bid)
    a.retain(bid)                       # second owner (prefix hit)
    assert a.refcount(bid) == 2
    a.release(bid)
    assert a.state(bid) is BlockState.ACTIVE
    a.release(bid)                      # last owner: indexed -> cached
    assert a.state(bid) is BlockState.CACHED
    assert a.num_evictable == 1
    a.retain(bid)                       # revival off the eviction list
    assert a.state(bid) is BlockState.ACTIVE and a.num_evictable == 0
    a.release(bid)
    assert a.state(bid) is BlockState.CACHED


def test_unindexed_release_returns_to_free_list():
    a = BlockAllocator(N_BLOCKS)
    bid = a.alloc()
    a.activate(bid)
    a.release(bid)
    assert a.state(bid) is BlockState.FREE
    assert a.num_free == a.capacity


def test_protocol_errors_on_out_of_order_transitions():
    a = BlockAllocator(N_BLOCKS)
    bid = a.alloc()
    a.activate(bid)
    with pytest.raises(KVPoolError, match="must be staged"):
        a.activate(bid)                 # double-activate
    a.release(bid)                      # unindexed -> free
    with pytest.raises(KVPoolError, match="never go negative"):
        a.release(bid)                  # release-after-free
    fresh = a.alloc()
    with pytest.raises(KVPoolError, match="active/cached"):
        a.retain(fresh)                 # staged blocks have one owner
    with pytest.raises(KVPoolError, match="must be active"):
        a.register(b"k", fresh)
    with pytest.raises(KVPoolError, match="out of range"):
        a.release(0)                    # the scratch block is reserved


def test_exhaustion_raises_then_eviction_recycles_lru():
    a = BlockAllocator(4)               # 3 allocatable
    bids = [a.alloc() for _ in range(3)]
    with pytest.raises(KVPoolError, match="exhausted"):
        a.alloc()
    # publish + release two in a known order -> LRU eviction order
    for i, bid in enumerate(bids[:2]):
        a.activate(bid)
        a.register(f"k{i}".encode(), bid)
        a.release(bid)
    assert a.num_evictable == 2
    got = a.alloc()                     # evicts bids[0] (least recent)
    assert got == bids[0]
    assert a.lookup(b"k0") is None      # evicted key dropped
    assert a.lookup(b"k1") == bids[1]   # survivor still indexed


def test_hash_consing_keeps_first_registration():
    a = BlockAllocator(N_BLOCKS)
    b1, b2 = a.alloc(), a.alloc()
    a.activate(b1), a.activate(b2)
    assert a.register(b"same", b1) == b1
    # concurrent staging of identical content: first registration wins,
    # the duplicate stays a private unshared copy
    assert a.register(b"same", b2) == b1
    a.release(b2)
    assert a.state(b2) is BlockState.FREE   # private copy frees outright


def test_chunk_keys_chain_full_preceding_context():
    """A chunk key covers content + offset + everything before it: the
    chain makes block j's key a function of blocks 0..j, so identical
    chunk content after DIFFERENT preceding context (or at a different
    offset) never collides — exactly the positional-exactness rule that
    keeps chunk splicing bit-exact."""
    bs = 4
    p1 = np.arange(1, 13, dtype=np.int32)
    assert chunk_keys(p1, 3, bs) == chunk_keys(p1, 3, bs)   # deterministic
    same_tail = np.concatenate([[99], p1[1:]]).astype(np.int32)
    k1, k2 = chunk_keys(p1, 3, bs), chunk_keys(same_tail, 3, bs)
    assert k1[0] != k2[0]
    assert k1[1] != k2[1] and k1[2] != k2[2]   # divergence propagates
    # same chunk content shifted by one block: different chain depth
    shifted = np.concatenate([p1[:4], p1]).astype(np.int32)
    assert chunk_keys(shifted, 4, bs)[1] != k1[0]
    # and chunk keys never collide with whole-prefix byte keys
    assert chunk_key(b"", p1[:4]) != prefix_key(p1, 4)


def test_multikey_aliases_share_one_bid_and_evict_together():
    """A block registered under BOTH its whole-prefix key and its chunk
    key is one physical block: either key resolves to it, lookup_any is
    ONE counted lookup, and eviction drops every alias at once."""
    a = BlockAllocator(4)                   # 3 allocatable
    bid = a.alloc()
    a.activate(bid)
    assert a.register(b"prefix", bid) == bid
    assert a.register(b"chunk", bid) == bid     # alias, not an error
    assert a.lookup(b"prefix") == a.lookup(b"chunk") == bid
    assert a.is_registered(bid)
    before = a.counters.prefix_block_lookups
    assert a.lookup_any([b"chunk", b"prefix"]) == bid
    assert a.counters.prefix_block_lookups == before + 1
    a.release(bid)                          # cached, evictable
    others = [a.alloc(), a.alloc()]
    got = a.alloc()                         # exhausted free list -> evict
    assert got == bid
    assert a.lookup(b"prefix") is None and a.lookup(b"chunk") is None
    assert not a.is_registered(bid)
    for b in others + [got]:
        a.release(b)


# ---------------------------------------------------------------------------
# Random interleavings: the model-checked allocator.
# ---------------------------------------------------------------------------

ACTIONS = ("alloc", "activate", "retain", "release", "register")


def _run_interleaving(seq, n_blocks=6):
    """Drive the allocator with a random action sequence against a
    shadow model; every legal op must agree with the model, every
    illegal op must raise and change nothing."""
    a = BlockAllocator(n_blocks)
    state = {}   # bid -> (BlockState, ref) shadow
    registered = set()
    key_ctr = [0]

    def snapshot():
        return ({b: (a.state(b), a.refcount(b))
                 for b in range(1, n_blocks)},
                a.num_free, a.num_evictable)

    for step, (act, tgt) in enumerate(seq):
        bids = sorted(state) or [1]
        bid = bids[tgt % len(bids)]
        before = snapshot()
        st_model = state.get(bid, (BlockState.FREE, 0))
        try:
            if act == "alloc":
                got = a.alloc()
                assert state.get(got, (BlockState.FREE, 0))[0] in (
                    BlockState.FREE, BlockState.CACHED)
                state[got] = (BlockState.STAGED, 1)
                registered.discard(got)
            elif act == "activate":
                a.activate(bid)
                assert st_model[0] is BlockState.STAGED
                state[bid] = (BlockState.ACTIVE, st_model[1])
            elif act == "retain":
                a.retain(bid)
                assert st_model[0] in (BlockState.ACTIVE, BlockState.CACHED)
                state[bid] = (BlockState.ACTIVE,
                              st_model[1] + 1 if st_model[0] is
                              BlockState.ACTIVE else 1)
            elif act == "release":
                a.release(bid)
                assert st_model[1] >= 1
                ref = st_model[1] - 1
                if ref > 0:
                    state[bid] = (st_model[0], ref)
                elif bid in registered:
                    state[bid] = (BlockState.CACHED, 0)
                else:
                    state[bid] = (BlockState.FREE, 0)
            elif act == "register":
                # mixed key families: whole-prefix-style byte keys and
                # chained chunk digests interleave in ONE index. A
                # second registration on an already-registered bid is an
                # ALIAS (legal since chunk addressing), and eviction
                # must drop every alias — the model tracks bids only,
                # so a stale alias would surface as a state divergence.
                if key_ctr[0] % 2:
                    key = chunk_key(b"prev%d" % key_ctr[0],
                                    np.asarray([key_ctr[0]], np.int32))
                else:
                    key = b"k%d" % key_ctr[0]
                key_ctr[0] += 1
                a.register(key, bid)
                assert st_model[0] is BlockState.ACTIVE
                registered.add(bid)
        except KVPoolError:
            # illegal per the model — and state must be untouched
            assert snapshot() == before, f"step {step}: {act} corrupted"
            continue
        # global invariants after every successful op
        in_use = sum(1 for s, _ in state.values()
                     if s in (BlockState.STAGED, BlockState.ACTIVE))
        assert a.in_use == in_use
        assert a.num_free + a.num_evictable + a.in_use == a.capacity
        for b, (s, r) in state.items():
            assert a.state(b) is s and a.refcount(b) == r, (step, b)
            assert r >= 0


def test_random_interleavings_seeded():
    rng = np.random.RandomState(0)
    for _ in range(60):
        seq = [(ACTIONS[rng.randint(len(ACTIONS))], int(rng.randint(8)))
               for _ in range(rng.randint(5, 60))]
        _run_interleaving(seq)


if HAS_HYPOTHESIS:

    @settings(max_examples=120, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(ACTIONS),
                              st.integers(0, 7)),
                    min_size=1, max_size=80))
    def test_random_interleavings_hypothesis(seq):
        _run_interleaving(seq)


# ---------------------------------------------------------------------------
# Manager: prefix splicing, COW isolation on the real device pool.
# ---------------------------------------------------------------------------

def _mgr(num_blocks=10, block_size=4):
    cfg = cfglib.get_smoke_config("nemotron-4-15b")
    api = get_model(cfg)
    return PagedKVManager(api, cfg, L.HOST, num_blocks=num_blocks,
                          block_size=block_size), cfg


def test_begin_publish_release_and_prefix_splice():
    mgr, _ = _mgr()
    prompt = np.arange(1, 11, dtype=np.int32)       # S=10, bs=4
    rb = mgr.begin_request(prompt, prompt.size + 3)  # 13 pos -> 4 blocks
    assert rb is not None and len(rb.bids) == 4
    assert rb.prefix_hit_blocks == 0
    mgr.publish_prompt(prompt, rb)
    # full blocks of prompt[:-1] (9 tokens -> 2 full blocks) registered
    assert mgr.alloc.lookup(prefix_key(prompt, 4)) == rb.bids[0]
    assert mgr.alloc.lookup(prefix_key(prompt, 8)) == rb.bids[1]
    # a same-prefix request splices both, shares physically
    rb2 = mgr.begin_request(prompt, prompt.size + 3)
    assert rb2.prefix_hit_blocks == 2
    assert rb2.bids[:2] == rb.bids[:2]
    assert rb2.bids[2:] != rb.bids[2:]
    assert mgr.alloc.refcount(rb.bids[0]) == 2
    mgr.release_request(rb)
    assert mgr.alloc.refcount(rb2.bids[0]) == 1     # rb2 still owns
    mgr.release_request(rb2)
    # published blocks stay cached; a third request still hits
    rb3 = mgr.begin_request(prompt, prompt.size + 3)
    assert rb3.prefix_hit_blocks == 2


def test_begin_request_is_atomic_under_exhaustion():
    mgr, _ = _mgr(num_blocks=4)                     # 3 allocatable
    prompt = np.arange(1, 6, dtype=np.int32)
    rb = mgr.begin_request(prompt, 8)               # 2 blocks
    assert rb is not None
    before = (mgr.alloc.num_free, mgr.alloc.in_use)
    assert mgr.begin_request(prompt, 12) is None    # needs 3, has 1
    assert (mgr.alloc.num_free, mgr.alloc.in_use) == before


def test_begin_request_atomic_when_hits_are_the_evictable_blocks():
    """Regression: availability is measured AFTER reviving cached prefix
    hits — a hit pulled off the eviction list must not be double-counted
    as still-evictable headroom, and a failed begin must re-cache the
    revived hits (not leak them active or raise mid-allocation)."""
    mgr, _ = _mgr(num_blocks=5, block_size=4)       # 4 allocatable
    prompt = np.arange(1, 10, dtype=np.int32)       # 2 full prefix blocks
    rb = mgr.begin_request(prompt, 9)               # 3 blocks
    mgr.publish_prompt(prompt, rb)
    mgr.release_request(rb)                         # 2 cached + 1 free
    filler_rb = mgr.begin_request(
        np.asarray([91], np.int32), 8)              # takes both free bids
    assert filler_rb is not None and len(filler_rb.bids) == 2
    # now free+evictable = 2, and BOTH are the cached prefix blocks a
    # same-prefix request will revive as hits. It needs 2 hits + 1
    # fresh: after the revivals nothing is left to allocate, so begin
    # must fail cleanly — pre-fix, can_alloc counted the hits as
    # still-evictable headroom and alloc() raised mid-loop.
    cached = [b for b in range(1, 5)
              if mgr.alloc.state(b) is kvp.BlockState.CACHED]
    assert len(cached) == 2
    assert mgr.begin_request(prompt, 12) is None    # no KVPoolError
    for b in cached:                                # hits re-cached
        assert mgr.alloc.state(b) is kvp.BlockState.CACHED
    assert mgr.alloc.in_use == 2                    # only the filler


def test_interior_hole_splice_and_chunk_counters():
    """The chunk-addressing payoff: after the LRU evicts a LEADING
    prompt block, a re-walk still splices the SURVIVING interior blocks
    (hit_idx sparse, prefix_hit_blocks 0) and staging owes only the
    hole; re-publication heals the index under both key families."""
    mgr, _ = _mgr(num_blocks=10, block_size=4)
    prompt = np.arange(1, 18, dtype=np.int32)       # S=17: 4 full blocks
    rb = mgr.begin_request(prompt, prompt.size)     # 5 blocks
    assert rb.hit_idx == ()
    first_bids = list(rb.bids)
    mgr.publish_prompt(prompt, rb)
    mgr.release_request(rb)
    assert mgr.alloc.evict_cached(1) == 1           # LRU = leading block
    rb2 = mgr.begin_request(prompt, prompt.size)
    assert rb2.prefix_hit_blocks == 0               # leading run broken
    assert rb2.hit_idx == (1, 2, 3)                 # survivors spliced
    assert rb2.bids[1:4] == first_bids[1:4]         # same physical blocks
    assert mgr.counters.chunk_interior_hits == 3
    assert mgr.counters.prompt_blocks == 8          # 4 walked per begin
    # publish re-registers ONLY the hole (hits are already indexed);
    # afterwards the full leading run hits again
    mgr.publish_prompt(prompt, rb2)
    mgr.release_request(rb2)
    rb3 = mgr.begin_request(prompt, prompt.size)
    assert rb3.prefix_hit_blocks == 4
    assert rb3.hit_idx == (0, 1, 2, 3)
    mgr.release_request(rb3)


def test_affinity_probes_side_effect_free_under_chunk_keys():
    """``prefix_affinity`` (leading run) and ``chunk_affinity`` (all
    warm blocks, interior included) are pure peeks: no lookup/hit
    counters, no LRU reordering — the router probes every replica per
    request, and probing must never perturb eviction order or stats."""
    mgr, _ = _mgr(num_blocks=10, block_size=4)
    prompt = np.arange(1, 14, dtype=np.int32)       # 3 full blocks
    rb = mgr.begin_request(prompt, prompt.size)
    mgr.publish_prompt(prompt, rb)
    mgr.release_request(rb)
    assert mgr.prefix_affinity(prompt) == 3
    assert mgr.chunk_affinity(prompt) == 3
    mgr.alloc.evict_cached(1)                       # hole at block 0
    before = (mgr.counters.prefix_block_lookups,
              mgr.counters.prefix_block_hits,
              mgr.counters.prompt_blocks)
    evict_order = list(mgr.alloc._evictable)
    for _ in range(3):
        assert mgr.prefix_affinity(prompt) == 0     # run broken at 0
        assert mgr.chunk_affinity(prompt) == 2      # interior still warm
    assert (mgr.counters.prefix_block_lookups,
            mgr.counters.prefix_block_hits,
            mgr.counters.prompt_blocks) == before
    assert list(mgr.alloc._evictable) == evict_order
    # unknown prompt: both report cold, still silently
    assert mgr.chunk_affinity(np.asarray([9, 9, 9, 9, 9], np.int32)) == 0


def test_publish_registers_both_key_families():
    """Every published full prompt block answers to its whole-prefix
    key AND its chained chunk key, and both resolve to one bid."""
    mgr, _ = _mgr(num_blocks=10, block_size=4)
    prompt = np.arange(1, 10, dtype=np.int32)       # 2 full blocks
    rb = mgr.begin_request(prompt, prompt.size + 2)
    mgr.publish_prompt(prompt, rb)
    cks = chunk_keys(prompt, 2, 4)
    for j in range(2):
        assert mgr.alloc.peek(prefix_key(prompt, (j + 1) * 4)) == rb.bids[j]
        assert mgr.alloc.peek(cks[j]) == rb.bids[j]
    mgr.release_request(rb)


def test_cow_isolates_shared_block_on_device():
    mgr, cfg = _mgr(num_blocks=10, block_size=4)
    prompt = np.arange(1, 10, dtype=np.int32)       # 2 full blocks
    rb = mgr.begin_request(prompt, prompt.size + 2)
    mgr.publish_prompt(prompt, rb)
    rb2 = mgr.begin_request(prompt, prompt.size + 2)
    shared = rb2.bids[0]
    assert shared == rb.bids[0]
    # stamp recognizable values into the shared block
    marker = jax.tree.map(
        lambda f, ax: f.at[(slice(None),) * ax + (shared,)].set(
            jnp.ones_like(jnp.take(f, shared, axis=ax))),
        mgr.pool.cache, mgr.pool.batch_axes)
    mgr.pool.cache = marker
    assert mgr.ensure_exclusive(rb2, 0)             # copies
    assert rb2.bids[0] != shared
    assert mgr.alloc.refcount(shared) == 1          # rb still owns it
    assert mgr.counters.cow_copies == 1
    # the copy carries the stamped content; the original is untouched;
    # a write into the copy does not reach the original
    for f, ax in zip(jax.tree.leaves(mgr.pool.cache),
                     jax.tree.leaves(mgr.pool.batch_axes)):
        np.testing.assert_array_equal(
            np.asarray(jnp.take(f, rb2.bids[0], axis=ax)),
            np.asarray(jnp.take(f, shared, axis=ax)))
    mgr.pool.cache = jax.tree.map(
        lambda f, ax: f.at[(slice(None),) * ax + (rb2.bids[0],)].set(
            2 * jnp.ones_like(jnp.take(f, rb2.bids[0], axis=ax))),
        mgr.pool.cache, mgr.pool.batch_axes)
    for f, ax in zip(jax.tree.leaves(mgr.pool.cache),
                     jax.tree.leaves(mgr.pool.batch_axes)):
        assert not np.array_equal(
            np.asarray(jnp.take(f, rb2.bids[0], axis=ax)),
            np.asarray(jnp.take(f, shared, axis=ax)))
    # exclusive block: second call is a no-op
    assert not mgr.ensure_exclusive(rb2, 0)


if HAS_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(2, 12), st.integers(1, 6)),
                    min_size=1, max_size=6),
           st.integers(0, 3))
    def test_request_lifecycle_never_leaks_blocks(reqs, share_seed):
        """Random begin/publish/cow/release traffic: afterwards every
        block is free or cached (nothing leaks), and refcounts of live
        requests' blocks were never negative along the way (release
        raises otherwise)."""
        a = BlockAllocator(64)
        bs = 4
        rng = np.random.RandomState(share_seed)
        live = []
        for plen, gen in reqs:
            prompt = rng.randint(0, 5, size=plen).astype(np.int32)
            need = -(-(plen + gen - 1) // bs)
            hits = []
            for j in range(min((plen - 1) // bs, need)):
                bid = a.lookup(prefix_key(prompt, (j + 1) * bs))
                if bid is None:
                    break
                hits.append(bid)
            if not a.can_alloc(need - len(hits)):
                continue
            for h in hits:
                a.retain(h)
            fresh = [a.alloc() for _ in range(need - len(hits))]
            for f in fresh:
                a.activate(f)
            for j in range(len(hits), min((plen - 1) // bs, need)):
                a.register(prefix_key(prompt, (j + 1) * bs),
                           (hits + fresh)[j])
            live.append(hits + fresh)
            if rng.rand() < 0.5 and live:
                for bid in live.pop(rng.randint(len(live))):
                    a.release(bid)
        for bids in live:
            for bid in bids:
                a.release(bid)
        assert a.in_use == 0
        assert a.num_free + a.num_evictable == a.capacity


# ---------------------------------------------------------------------------
# Device pool probing + gather reconstruction.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,int8", [
    ("nemotron-4-15b", False),
    ("nemotron-4-15b", True),
    ("deepseek-v3-671b", False),
])
def test_pool_axes_probe_and_gather_roundtrip(arch, int8):
    """Probed batch/length axes address every leaf of every cache
    family, and gather() reconstructs exactly the dense slab layout."""
    cfg = cfglib.get_smoke_config(arch)
    if int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=jnp.int8)
    api = get_model(cfg)
    bs, nb = 4, 3
    pool = kvp.KVPool(api, cfg, L.HOST, num_blocks=1 + 2 * nb,
                      block_size=bs)
    for leaf, ba, la in zip(jax.tree.leaves(pool.cache),
                            jax.tree.leaves(pool.batch_axes),
                            jax.tree.leaves(pool.length_axes)):
        assert leaf.shape[ba] == 1 + 2 * nb, (arch, leaf.shape, ba)
        assert leaf.shape[la] == bs, (arch, leaf.shape, la)
        assert ba < la
    # fill with recognizable values, gather, compare against reshaping
    filled = jax.tree.map(
        lambda f: jnp.arange(f.size, dtype=jnp.float32).reshape(
            f.shape).astype(f.dtype), pool.cache)
    pool.cache = filled
    tables = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
    dense = pool.gather(tables)
    for g, f, ba, la in zip(jax.tree.leaves(dense),
                            jax.tree.leaves(filled),
                            jax.tree.leaves(pool.batch_axes),
                            jax.tree.leaves(pool.length_axes)):
        assert g.shape[ba] == 2 and g.shape[la] == nb * bs
        # row 0, logical position p lives in block tables[0][p // bs]
        for p in (0, bs, nb * bs - 1):
            src = jnp.take(jnp.take(f, tables[0][p // bs], axis=ba),
                           p % bs, axis=la - 1)
            got = jnp.take(jnp.take(g, 0, axis=ba), p, axis=la - 1)
            np.testing.assert_array_equal(np.asarray(src), np.asarray(got))


# ---------------------------------------------------------------------------
# Lazy growth, forced eviction, spill/restore — the preemption substrate.
# ---------------------------------------------------------------------------

def test_ensure_span_grows_lazily_and_atomically():
    mgr, _ = _mgr(num_blocks=5, block_size=4)       # 4 allocatable
    prompt = np.arange(1, 6, dtype=np.int32)        # S=5
    rb = mgr.begin_request(prompt, 4)               # 1 block staged
    assert len(rb.bids) == 1
    assert mgr.ensure_span(rb, 4)                   # covered: no-op
    assert len(rb.bids) == 1
    assert mgr.ensure_span(rb, 9)                   # grow to 3 blocks
    assert len(rb.bids) == 3 and rb.span == 12
    for b in rb.bids[1:]:                           # growth goes active
        assert mgr.alloc.state(b) is BlockState.ACTIVE
        assert mgr.alloc.refcount(b) == 1
    before = (mgr.alloc.num_free, mgr.alloc.in_use, list(rb.bids))
    assert not mgr.ensure_span(rb, 24)              # needs 6 > capacity
    after = (mgr.alloc.num_free, mgr.alloc.in_use, list(rb.bids))
    assert after == before                          # partial growth unwound
    mgr.release_request(rb)
    assert mgr.alloc.in_use == 0


def test_evict_cached_flushes_lru_first():
    mgr, _ = _mgr(num_blocks=8, block_size=4)
    p1 = np.arange(1, 10, dtype=np.int32)           # 2 full prefix blocks
    p2 = np.arange(50, 59, dtype=np.int32)
    for p in (p1, p2):
        rb = mgr.begin_request(p, p.size)
        mgr.publish_prompt(p, rb)
        mgr.release_request(rb)
    assert mgr.alloc.num_evictable == 4
    assert mgr.alloc.evict_cached(1) == 1           # LRU = p1's first block
    assert mgr.alloc.lookup(prefix_key(p1, 4)) is None
    assert mgr.alloc.lookup(prefix_key(p2, 4)) is not None
    n = mgr.alloc.evict_cached()                    # flush the rest
    assert n == 3 and mgr.alloc.num_evictable == 0
    assert mgr.alloc.num_free + 0 == mgr.alloc.capacity
    assert mgr.counters.evictions == 4


def test_spill_restore_roundtrip_is_bit_exact():
    """Manager-level spill -> restore: block content round-trips
    through host numpy exactly, a surviving prefix block is re-spliced
    (same physical block), and a flushed index forces the rewrite path
    — both restores yield identical device bytes."""
    mgr, _ = _mgr(num_blocks=8, block_size=4)
    prompt = np.arange(1, 10, dtype=np.int32)       # S=9: 2 full blocks
    rb = mgr.begin_request(prompt, 12)              # 3 blocks
    mgr.publish_prompt(prompt, rb)
    mgr.pool.cache = jax.tree.map(
        lambda f: jnp.arange(f.size, dtype=jnp.float32).reshape(
            f.shape).astype(f.dtype), mgr.pool.cache)
    want = [np.asarray(jnp.take(f, b, axis=ax))
            for b in rb.bids
            for f, ax in zip(jax.tree.leaves(mgr.pool.cache),
                             jax.tree.leaves(mgr.pool.batch_axes))]
    payload = mgr.spill_request(rb, 12)
    assert payload["n_blocks"] == 3 and payload["nbytes"] > 0
    assert mgr.alloc.in_use == 0                    # victim pins nothing
    for flush in (False, True):
        if flush:
            mgr.alloc.evict_cached()                # kill every splice
        rb2 = mgr.restore_request(prompt, payload)
        assert rb2 is not None and len(rb2.bids) == 3
        assert rb2.prefix_hit_blocks == (2 if not flush else 0)
        got = [np.asarray(jnp.take(f, b, axis=ax))
               for b in rb2.bids
               for f, ax in zip(jax.tree.leaves(mgr.pool.cache),
                                jax.tree.leaves(mgr.pool.batch_axes))]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
        # restore re-publishes: a sibling can splice the prompt blocks
        assert mgr.alloc.lookup(prefix_key(prompt, 4)) == rb2.bids[0]
        mgr.spill_request(rb2, 12)                  # spill again for round 2
    assert mgr.alloc.in_use == 0


def test_restore_unwinds_atomically_when_pool_is_full():
    mgr, _ = _mgr(num_blocks=5, block_size=4)       # 4 allocatable
    prompt = np.arange(1, 6, dtype=np.int32)
    rb = mgr.begin_request(prompt, 8)               # 2 blocks
    payload = mgr.spill_request(rb, 8)
    mgr.alloc.evict_cached()                        # no splices survive
    hog = mgr.begin_request(np.asarray([77], np.int32), 12)  # 3 of 4
    before = (mgr.alloc.num_free, mgr.alloc.in_use)
    assert mgr.restore_request(prompt, payload) is None   # needs 2, has 1
    assert (mgr.alloc.num_free, mgr.alloc.in_use) == before
    mgr.release_request(hog)
    assert mgr.restore_request(prompt, payload) is not None  # payload kept


def _injecting_hook(fail_at):
    """A fault hook failing the Nth alloc (1-based), counting calls."""
    calls = [0]

    def hook():
        calls[0] += 1
        return calls[0] == fail_at

    return hook, calls


def test_begin_request_rolls_back_on_injected_failure_at_every_step():
    """The satellite bugfix, exhaustively: begin_request's fresh-alloc
    loop can die on ANY allocation (the fault hook fails the Nth); the
    partially built request must fully unwind — hits re-cached, fresh
    blocks freed, counters balanced — and a later begin succeed."""
    for fail_at in range(1, 5):
        mgr, _ = _mgr(num_blocks=8, block_size=4)
        prompt = np.arange(1, 10, dtype=np.int32)   # 2 full prefix blocks
        seed_rb = mgr.begin_request(prompt, prompt.size)
        mgr.publish_prompt(prompt, seed_rb)
        mgr.release_request(seed_rb)                # 2 cached hits ready
        hook, calls = _injecting_hook(fail_at)
        mgr.alloc.fault_hook = hook
        before = {b: (mgr.alloc.state(b), mgr.alloc.refcount(b))
                  for b in range(1, 8)}
        rb = mgr.begin_request(prompt, 24)          # 2 hits + 4 fresh
        if calls[0] >= fail_at:                     # the hook fired
            assert rb is None
            after = {b: (mgr.alloc.state(b), mgr.alloc.refcount(b))
                     for b in range(1, 8)}
            assert after == before, f"fail_at={fail_at} corrupted state"
        else:
            assert rb is not None
        mgr.alloc.fault_hook = None
        rb2 = mgr.begin_request(prompt, 24)
        assert (rb2 is not None) or (rb is not None)


if HAS_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 8), st.integers(2, 12), st.integers(4, 28))
    def test_begin_request_injection_hypothesis(fail_at, plen, span):
        """Property form: for ANY (failing allocation index, prompt
        length, span), a failed begin_request leaves the allocator
        exactly as it found it."""
        a_cfg = cfglib.get_smoke_config("nemotron-4-15b")
        api = get_model(a_cfg)
        mgr = PagedKVManager(api, a_cfg, L.HOST, num_blocks=8,
                             block_size=4)
        prompt = np.arange(1, plen + 1, dtype=np.int32)
        hook, calls = _injecting_hook(fail_at)
        mgr.alloc.fault_hook = hook
        before = (mgr.alloc.num_free, mgr.alloc.num_evictable,
                  mgr.alloc.in_use)
        rb = mgr.begin_request(prompt, max(span, plen))
        if rb is None:
            assert (mgr.alloc.num_free, mgr.alloc.num_evictable,
                    mgr.alloc.in_use) == before
        else:
            assert mgr.alloc.in_use == len(rb.bids)
