"""SidebarRing protocol: random interleavings at depths 2-5.

Property coverage for the T-deep ring discipline the pipelined engine
relies on:

  * random legal/illegal interleavings of acquire / to_host /
    to_accelerator / release never corrupt the buffer's free list —
    illegal transitions raise ``SidebarProtocolError`` and leave both the
    ring and the allocator exactly as they were;
  * reuse-before-release (acquiring tile t while tile t-depth is still
    in flight) raises at every depth;
  * a drained ring frees cleanly: repeated build/run/free cycles recycle
    the same placements (the bump cursor does not creep).

Hypothesis-driven when available; seeded-random versions always run.
"""

import numpy as np
import pytest

from repro.core import Owner, SidebarBuffer, SidebarRing
from repro.core.sidebar import CONTROL_BYTES, SidebarProtocolError, _align

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

DEPTHS = (2, 3, 4, 5)
ACTIONS = ("acquire", "to_host", "to_accelerator", "release")
OPERAND_NBYTES = 192
RESULT_NBYTES = 160


def _capacity(depth: int) -> int:
    return CONTROL_BYTES + depth * (
        _align(OPERAND_NBYTES) + _align(RESULT_NBYTES)
    ) + 1024


def _free_list_invariants(sb: SidebarBuffer) -> None:
    """The allocator's free list must stay sorted, aligned, disjoint, and
    inside [CONTROL_BYTES, cursor)."""
    spans = list(sb._free)
    assert spans == sorted(spans)
    end_prev = CONTROL_BYTES
    for off, size in spans:
        assert off % 128 == 0 and size % 128 == 0 and size > 0
        assert off >= end_prev  # disjoint, non-overlapping
        end_prev = off + size
    assert end_prev <= sb._cursor <= sb.capacity
    # free spans never overlap a live region
    for region in sb.regions():
        for off, size in spans:
            assert region.end <= off or region.offset >= off + size


def _drain(ring: SidebarRing) -> None:
    """Drive every slot to 'free' with legal transitions only."""
    order = {"filled": ("to_host", "to_accelerator", "release"),
             "at_host": ("to_accelerator", "release"),
             "returned": ("release",), "free": ()}
    for slot in ring.slots:
        for action in order[slot.state]:
            getattr(ring, action)(slot)


def _walk(depth: int, choices: list[int]) -> None:
    """Apply a random action stream; legal steps mutate, illegal steps
    must raise and leave the protocol state untouched."""
    sb = SidebarBuffer(_capacity(depth))
    ring = SidebarRing(sb, "ring", OPERAND_NBYTES, RESULT_NBYTES,
                       depth=depth)
    next_tile = 0
    payload = np.zeros(OPERAND_NBYTES // 4, np.float32)
    for c in choices:
        action = ACTIONS[c % len(ACTIONS)]
        slot = ring.slots[(c // len(ACTIONS)) % depth]
        before = [(s.label, s.state) for s in ring.slots]
        owners_before = {
            s.label: (sb.region_owner(s.operand.name),
                      sb.region_owner(s.result.name))
            for s in ring.slots
        }
        try:
            if action == "acquire":
                legal = ring.slot(next_tile).state == "free"
                got = ring.acquire(next_tile)
                sb.write(Owner.ACCELERATOR, got.operand.name, payload)
                next_tile += 1
            elif action == "to_host":
                legal = slot.state == "filled"
                ring.to_host(slot)
            elif action == "to_accelerator":
                legal = slot.state == "at_host"
                ring.to_accelerator(slot)
            else:
                legal = slot.state == "returned"
                ring.release(slot)
            assert legal, f"{action} should have raised"
        except SidebarProtocolError:
            assert not legal, f"legal {action} raised"
            # an illegal transition must not move any slot or owner
            assert [(s.label, s.state) for s in ring.slots] == before
            assert owners_before == {
                s.label: (sb.region_owner(s.operand.name),
                          sb.region_owner(s.result.name))
                for s in ring.slots
            }
        _free_list_invariants(sb)
    _drain(ring)
    ring.free()
    _free_list_invariants(sb)
    # placements recycle: an identical ring reuses the freed area and the
    # bump cursor has not crept
    cursor = sb._cursor
    again = SidebarRing(sb, "again", OPERAND_NBYTES, RESULT_NBYTES,
                        depth=depth)
    _drain(again)
    again.free()
    assert sb._cursor <= cursor
    _free_list_invariants(sb)


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("seed", range(6))
def test_random_interleavings_keep_free_list_coherent_seeded(depth, seed):
    rng = np.random.default_rng(1000 * depth + seed)
    _walk(depth, [int(v) for v in rng.integers(0, 4 * depth, size=200)])


if HAS_HYPOTHESIS:

    @given(
        depth=st.sampled_from(DEPTHS),
        choices=st.lists(st.integers(min_value=0, max_value=4 * 5 - 1),
                         max_size=300),
    )
    @settings(max_examples=60, deadline=None)
    def test_random_interleavings_keep_free_list_coherent_property(
        depth, choices
    ):
        _walk(depth, choices)


@pytest.mark.parametrize("depth", DEPTHS)
def test_reuse_before_release_raises_at_every_depth(depth):
    sb = SidebarBuffer(_capacity(depth))
    ring = SidebarRing(sb, "ring", OPERAND_NBYTES, RESULT_NBYTES,
                       depth=depth)
    for t in range(depth):
        slot = ring.acquire(t)
        ring.to_host(slot)
    # tile `depth` maps back onto tile 0's still-in-flight slot
    with pytest.raises(SidebarProtocolError, match="reused before release"):
        ring.acquire(depth)
    # ...at every pipeline stage of the victim slot's lifecycle
    victim = ring.slot(0)
    ring.to_accelerator(victim)
    with pytest.raises(SidebarProtocolError, match="reused before release"):
        ring.acquire(depth)
    ring.release(victim)
    assert ring.acquire(depth) is victim  # released -> legal again


def test_ring_depth_validation():
    sb = SidebarBuffer(_capacity(2))
    with pytest.raises(ValueError, match="depth"):
        SidebarRing(sb, "bad", 64, 64, depth=0)
