"""RAG serving: retrieval as a host-side flexible op over the paged pool.

The subsystem invariant mirrors the paged scheduler's: RAG is prompt
ASSEMBLY plus scheduling, never numerics. A drain through
``submit_query`` — retrieval between segment dispatches, chunk-level KV
splicing, overlap on or off — must produce exactly the tokens that
plain ``submit()`` of the same assembled prompts produces (greedy and
sampled, GQA and MLA+MoE), which the paged suite in turn pins to solo
decode. On top of that, the payoff must be real: distinct queries whose
retrieved sets overlap share chunk-addressed KV blocks
(``retrieval_chunk_hits > 0``), and an LRU-evicted leading block no
longer voids the surviving interior blocks (interior-hole splicing).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.models.registry import get_model
from repro.retrieval import (
    ChunkedCorpus,
    EmbeddingIndex,
    RagPipeline,
    make_toy_corpus,
)

ARCHS = ["nemotron-4-15b", "deepseek-v3-671b"]
BS = 8


def _cfg(arch: str):
    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # no-drop capacity: co-scheduled rows must not change expert
        # routing — the same bit-parity caveat as prompt bucketing
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        out[arch] = (cfg, api.init(jax.random.PRNGKey(0), cfg))
    return out


def _rag(cfg, *, block_size=BS, top_k=2, chunk_tokens=BS, n_docs=4,
         doc_len=32, seed=0, **kw):
    docs = make_toy_corpus(cfg.vocab_size, n_docs=n_docs, doc_len=doc_len,
                           seed=seed)
    corpus = ChunkedCorpus(docs, chunk_tokens=chunk_tokens)
    index = EmbeddingIndex(corpus, vocab_size=cfg.vocab_size, seed=seed)
    return docs, RagPipeline(index, system_prefix=[5, 6, 7],
                             block_size=block_size, top_k=top_k, **kw)


def _server(cfg, params, *, rag=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("block_size", BS)
    kw.setdefault("prefill_chunk", BS)
    kw.setdefault("segment", 4)
    return PagedContinuousBatchingServer(cfg, params, rag=rag, **kw)


# ---------------------------------------------------------------------------
# Pipeline: determinism, layout, validation (no model needed).
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_block_aligned():
    cfg = _cfg("nemotron-4-15b")
    _, pipe1 = _rag(cfg)
    _, pipe2 = _rag(cfg)
    q = np.asarray([11, 12, 13], np.int32)
    a, b = pipe1.assemble(q), pipe2.assemble(q)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert [c.chunk_id for c in a.chunks] == [c.chunk_id for c in b.chunks]
    # layout contract: system prefix padded to a block multiple, every
    # chunk starts on a block boundary and covers whole blocks
    assert pipe1.system_prefix.size % BS == 0
    for c in a.chunks:
        assert c.offset % BS == 0
        assert c.tokens.size % BS == 0
        np.testing.assert_array_equal(
            a.tokens[c.offset:c.offset + c.tokens.size], c.tokens)
    np.testing.assert_array_equal(a.tokens[-q.size:], q)
    assert a.tokens.size == pipe1.prompt_len_for + q.size
    # canonical order: chunk ids ascend, independent of score order
    ids = [c.chunk_id for c in a.chunks]
    assert ids == sorted(ids)
    # chunk_blocks names exactly the retrieved-chunk block indices
    blocks = a.chunk_blocks(BS)
    assert len(blocks) == sum(c.tokens.size // BS for c in a.chunks)
    assert min(blocks) == pipe1.system_prefix.size // BS


def test_index_retrieves_own_document_first():
    cfg = _cfg("nemotron-4-15b")
    docs, pipe = _rag(cfg, n_docs=4, doc_len=32)
    for d in range(4):
        ranked = pipe.index.search(docs[d][:8], 2)
        top = pipe.index.corpus.chunks[ranked[0][0]]
        assert top.doc == d, f"query from doc {d} ranked doc {top.doc} first"


def test_alignment_validation():
    cfg = _cfg("nemotron-4-15b")
    docs = make_toy_corpus(cfg.vocab_size, n_docs=2, doc_len=32)
    corpus = ChunkedCorpus(docs, chunk_tokens=6)     # not a multiple of 8
    index = EmbeddingIndex(corpus, vocab_size=cfg.vocab_size)
    with pytest.raises(ValueError, match="multiple of block_size"):
        RagPipeline(index, system_prefix=[1], block_size=8)
    with pytest.raises(ValueError, match="full chunk"):
        ChunkedCorpus([np.asarray([1, 2], np.int32)], chunk_tokens=8)


# ---------------------------------------------------------------------------
# Scheduler: bit-exactness, chunk reuse, overlap, validation.
# ---------------------------------------------------------------------------

def _queries(docs, rng, n):
    """Queries drawn from document content so retrieval sets overlap
    across distinct queries (same docs -> same chunks)."""
    out = []
    for i in range(n):
        d = docs[rng.randint(len(docs) // 2)]       # concentrate on 2 docs
        lo = rng.randint(0, d.size - 6)
        out.append(d[lo:lo + rng.randint(3, 7)].copy())
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_rag_drain_bit_exact_vs_plain_submit(arch, served):
    """Greedy and sampled queries through submit_query produce EXACTLY
    the tokens plain submit() of the same assembled prompts produces —
    retrieval, chunk splicing and overlap never touch numerics."""
    cfg, params = served[arch]
    docs, pipe = _rag(cfg)
    rag_srv = _server(cfg, params, rag=pipe)
    rng = np.random.RandomState(7)
    qs = _queries(docs, rng, 5)
    samples = [None, SamplingParams(temperature=0.8, seed=11), None,
               SamplingParams(temperature=1.1, top_k=20, seed=3), None]
    rids = [rag_srv.submit_query(q, 5, s) for q, s in zip(qs, samples)]
    done = {r.rid: r for r in rag_srv.run()}
    assert sorted(done) == sorted(rids)
    assert rag_srv.stats.retrievals == len(qs)
    # the same assembled prompts through the plain path, fresh pool
    plain = _server(cfg, params)
    plain_rids = [plain.submit(rag_srv.rag_results[rid].tokens, 5, s)
                  for rid, s in zip(rids, samples)]
    plain_done = {r.rid: r for r in plain.run()}
    for rid, prid in zip(rids, plain_rids):
        np.testing.assert_array_equal(
            done[rid].tokens, plain_done[prid].tokens,
            err_msg=f"{arch} rid {rid}: RAG drain != plain submit")


def test_chunk_reuse_across_distinct_queries(served):
    """DISTINCT queries whose retrieved sets overlap splice each other's
    chunk blocks: nonzero retrieval_chunk_hits, and the hit rate the
    stats report is the block-level fraction."""
    cfg, params = served["nemotron-4-15b"]
    docs, pipe = _rag(cfg)
    srv = _server(cfg, params, rag=pipe)
    # three different queries into the same document -> same chunks
    for q in (docs[0][:5], docs[0][10:16], docs[0][3:9]):
        srv.submit_query(q, 4)
    srv.run()
    st = srv.stats
    assert st.retrieval_chunk_blocks > 0
    assert st.retrieval_chunk_hits > 0, "no chunk-level reuse"
    assert 0 < st.retrieval_chunk_hit_rate <= 1
    # block-granular prefix accounting (the satellite fix): denominator
    # is prompt blocks walked, old lookups-based rate kept deprecated
    assert st.prefix_prompt_blocks >= st.prefix_block_hits > 0
    assert 0 < st.prefix_hit_rate <= 1
    assert 0 < st.prefix_lookup_hit_rate <= 1
    assert "retrieval" in st.summary()


def test_overlap_on_off_token_equality(served):
    """rag_overlap=True (retrieval hidden behind the in-flight segment)
    and rag_overlap=False (serial retrieval) produce identical tokens;
    the overlap arm actually overlaps when queries arrive mid-decode."""
    cfg, params = served["nemotron-4-15b"]
    tokens = {}
    for overlap in (True, False):
        docs, pipe = _rag(cfg)
        srv = _server(cfg, params, rag=pipe, rag_overlap=overlap)
        r0 = srv.submit_query(docs[0][:5], 24)      # long: keeps decoding
        done = srv.step()
        late = [srv.submit_query(docs[1][:6], 6),
                srv.submit_query(docs[0][3:9], 6)]
        while srv._has_work():
            done += srv.step(draining=True)
        tokens[overlap] = {r.rid: r.tokens for r in done}
        assert sorted(tokens[overlap]) == sorted([r0] + late)
        if overlap:
            assert srv.stats.retrieval_overlapped == 2
            assert srv.stats.retrieval_overlap_frac > 0
        else:
            assert srv.stats.retrieval_overlapped == 0
    for rid in tokens[True]:
        np.testing.assert_array_equal(tokens[True][rid], tokens[False][rid])


def test_interior_hole_splice_end_to_end(served):
    """LRU eviction of a LEADING prompt block no longer voids the
    surviving later blocks: the re-walk splices them at their interior
    chunk boundaries, staging prefills only the hole, and the drain
    stays bit-exact with the cold run."""
    cfg, params = served["nemotron-4-15b"]
    srv = _server(cfg, params, num_slots=1, block_size=4, prefill_chunk=4,
                  max_len=64)
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, size=17).astype(np.int32)
    srv.submit(prompt, 4)
    (r0,) = srv.run()
    assert srv.mgr.alloc.evict_cached(1) == 1       # LRU = leading block
    srv.submit(prompt, 4)
    (r1,) = srv.run()
    np.testing.assert_array_equal(r0.tokens, r1.tokens)
    assert srv.stats.chunk_interior_hits >= 3, "interior blocks recomputed"


def test_submit_query_validation(served):
    cfg, params = served["nemotron-4-15b"]
    plain = _server(cfg, params)
    with pytest.raises(ValueError, match="needs a RagPipeline"):
        plain.submit_query([1, 2], 4)
    docs, pipe = _rag(cfg)
    srv = _server(cfg, params, rag=pipe)
    with pytest.raises(ValueError, match="empty query"):
        srv.submit_query([], 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit_query([1], 0)
    # eager length check: assembled size is deterministic pre-retrieval
    with pytest.raises(ValueError, match="exceeds max_len"):
        srv.submit_query(np.arange(60, dtype=np.int32), 30)
    # pipeline/pool block-size mismatch refused at construction
    with pytest.raises(ValueError, match="block_size"):
        _server(cfg, params, rag=pipe, block_size=4)


def test_cancel_parked_query(served):
    """A query cancelled before its retrieval turn vanishes: never
    retrieved, never decoded, load drops immediately."""
    cfg, params = served["nemotron-4-15b"]
    docs, pipe = _rag(cfg)
    srv = _server(cfg, params, rag=pipe)
    keep = srv.submit_query(docs[0][:5], 3)
    drop = srv.submit_query(docs[1][:5], 3)
    assert srv.load == 2
    assert srv.cancel(drop)
    assert srv.load == 1
    assert not srv.cancel(drop)
    done = srv.run()
    assert [r.rid for r in done] == [keep]
    assert srv.stats.retrievals == 1
    assert srv.stats.cancelled == 1
