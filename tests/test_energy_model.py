"""Energy-model invariants — hypothesis property tests.

The analytical model must be *ordered* the way the paper's measurements
are, for any workload in a broad parameter space, not just LeNet.
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    account,
    estimate,
)


def _graph(b, d, f, act, itemsize=4):
    def mm(w, x):
        return x

    return LayerGraph(
        name="g",
        ops=(
            StaticOp("w1", mm, (b, f), flops=2 * b * d * f,
                     weight_bytes=d * f * itemsize),
            FlexibleOp(act, (b, f)),
            StaticOp("w2", mm, (b, d), flops=2 * b * f * d,
                     weight_bytes=f * d * itemsize),
        ),
        in_shape=(b, d),
        itemsize=itemsize,
    )


dims = st.integers(min_value=1, max_value=64).map(lambda v: v * 8)
acts = st.sampled_from(["relu", "sigmoid", "tanh", "softplus", "gelu", "silu"])


@given(b=dims, d=dims, f=dims, act=acts)
@settings(max_examples=80, deadline=None)
def test_flexible_dma_never_cheaper(b, d, f, act):
    g = _graph(b, d, f, act)
    e = {m: estimate(account(g, m)) for m in ExecutionMode}
    assert e[ExecutionMode.FLEXIBLE_DMA].energy_j >= e[ExecutionMode.SIDEBAR].energy_j
    assert e[ExecutionMode.FLEXIBLE_DMA].latency_s >= e[ExecutionMode.SIDEBAR].latency_s
    assert e[ExecutionMode.FLEXIBLE_DMA].edp >= e[ExecutionMode.SIDEBAR].edp


@given(b=dims, d=dims, f=dims, act=acts)
@settings(max_examples=80, deadline=None)
def test_sidebar_close_to_monolithic_and_above(b, d, f, act):
    g = _graph(b, d, f, act)
    e = {m: estimate(account(g, m)) for m in ExecutionMode}
    mono, sb = e[ExecutionMode.MONOLITHIC], e[ExecutionMode.SIDEBAR]
    # sidebar pays a nonnegative, bounded premium over fixed-function HW
    assert sb.energy_j >= mono.energy_j * 0.999
    assert sb.edp <= mono.edp * 3.0  # stays the same order of magnitude


@given(b=dims, d=dims, f=dims)
@settings(max_examples=40, deadline=None)
def test_costlier_activation_widens_dma_gap(b, d, f):
    """Paper §6.1: softplus widens the flexible-DMA gap more than the
    sidebar gap (both measured against monolithic)."""
    def gaps(act):
        g = _graph(b, d, f, act)
        e = {m: estimate(account(g, m)) for m in ExecutionMode}
        mono = e[ExecutionMode.MONOLITHIC].latency_s
        return (
            e[ExecutionMode.FLEXIBLE_DMA].latency_s - mono,
            e[ExecutionMode.SIDEBAR].latency_s - mono,
        )

    dma_relu, sb_relu = gaps("relu")
    dma_sp, sb_sp = gaps("softplus")
    assert dma_sp - dma_relu >= sb_sp - sb_relu  # DMA gap grows faster


@given(b=dims, d=dims, f=dims, act=acts, scale=st.integers(2, 8))
@settings(max_examples=40, deadline=None)
def test_energy_monotone_in_workload(b, d, f, act, scale):
    small = estimate(account(_graph(b, d, f, act), ExecutionMode.SIDEBAR))
    big = estimate(account(_graph(b * scale, d, f, act), ExecutionMode.SIDEBAR))
    assert big.energy_j > small.energy_j
    assert big.latency_s >= small.latency_s


@given(b=dims, d=dims, f=dims, act=acts)
@settings(max_examples=40, deadline=None)
def test_breakdown_sums_to_total(b, d, f, act):
    for m in ExecutionMode:
        e = estimate(account(_graph(b, d, f, act), m))
        total = e.e_hbm_j + e.e_sidebar_j + e.e_compute_j + e.e_static_j
        assert abs(total - e.energy_j) < 1e-12 * max(1.0, e.energy_j)
