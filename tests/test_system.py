"""End-to-end behaviour tests for the paper's system.

The paper's LeNet workload through the full engine in all three modes:
numerics identical, costs ordered as in Figures 6-8, sidebar capacity
respected, policy choices sane. Plus the multi-device distribution path
(run in a subprocess so the main test process keeps 1 device).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_TABLE,
    AutoPolicy,
    ExecutionMode,
    account_model,
    estimate,
    normalized_edp,
    run,
)
from repro.models import lenet


@pytest.fixture(scope="module")
def lenet_setup():
    lenet.register_pooling(DEFAULT_TABLE)
    params = lenet.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 32, 32), jnp.float32)
    graphs = lenet.to_layer_graphs(batch=8, activation="relu")
    return params, x, graphs


def test_lenet_three_modes_equal(lenet_setup):
    params, x, graphs = lenet_setup
    eng_params = lenet.engine_params(params)
    outs = {}
    for mode in ExecutionMode:
        out = x
        for g in graphs:
            out = run(g, eng_params, out, mode, DEFAULT_TABLE).output
        outs[mode] = np.asarray(out)
    ref = lenet.forward(params, x, DEFAULT_TABLE.lookup("relu"))
    for mode, o in outs.items():
        np.testing.assert_allclose(o, np.asarray(ref), rtol=1e-4, atol=1e-5,
                                   err_msg=str(mode))


@pytest.mark.parametrize("act", ["relu", "softplus"])
def test_lenet_paper_bands(lenet_setup, act):
    """Paper §6: flexible-DMA +8-14% latency / +32% energy / ~+50% EDP;
    sidebar <=2% latency / +6% energy / +7% EDP. Our hardware model is a
    TPU not the paper's gem5 SoC, so we assert the bands loosely: the
    ordering, the sign, and the magnitude class."""
    graphs = lenet.to_layer_graphs(batch=256, activation=act)
    ests = {
        m.value: estimate(account_model(graphs, m, DEFAULT_TABLE))
        for m in ExecutionMode
    }
    lat = {k: v.latency_s for k, v in ests.items()}
    edp = normalized_edp(ests)
    # ordering
    assert lat["monolithic"] <= lat["sidebar"] < lat["flexible_dma"]
    # sidebar latency within ~10% of monolithic; DMA at least 8% worse
    assert lat["sidebar"] / lat["monolithic"] < 1.10
    assert lat["flexible_dma"] / lat["monolithic"] > 1.08
    # EDP: sidebar slight increase; flexible-DMA >= ~1.3x
    assert edp["sidebar"] < 1.25
    assert edp["flexible_dma"] > 1.30


def test_lenet_softplus_widens_dma_gap(lenet_setup):
    """Paper §6.1: a costlier activation widens the flexible-DMA gap while
    the sidebar stays ~flat. We assert the ABSOLUTE latency gaps (robust
    in any hardware regime); the paper's relative 8->14% widening is a
    property of its gem5 SoC regime where accelerator time ~ DMA time —
    on a TPU-class chip the base DMA penalty is so large (~9x) that the
    ratio saturates (see EXPERIMENTS.md §Paper-validation)."""
    def gaps(act):
        graphs = lenet.to_layer_graphs(batch=256, activation=act)
        e = {m: estimate(account_model(graphs, m, DEFAULT_TABLE))
             for m in ExecutionMode}
        mono = e[ExecutionMode.MONOLITHIC].latency_s
        return (e[ExecutionMode.FLEXIBLE_DMA].latency_s - mono,
                e[ExecutionMode.SIDEBAR].latency_s - mono)

    dma_r, sb_r = gaps("relu")
    dma_s, sb_s = gaps("softplus")
    assert dma_s > dma_r                     # DMA gap widens
    assert (sb_s - sb_r) < (dma_s - dma_r)   # sidebar stays ~flat


def test_auto_policy_picks_sidebar_for_lenet(lenet_setup):
    _, _, graphs = lenet_setup
    policy = AutoPolicy(table=DEFAULT_TABLE)
    modes = [policy(g) for g in graphs]
    sidebar_modes = (ExecutionMode.SIDEBAR, ExecutionMode.SIDEBAR_PIPELINED)
    assert all(m in sidebar_modes for m in modes)


@pytest.mark.slow
def test_multi_device_training_subprocess():
    """Sharded FSDP x TP train step on 8 host devices — must run and the
    loss must decrease. Subprocess so this test owns its device count."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import configs as cfglib
from repro.configs.base import TrainConfig, ShapeCell
from repro.launch.train import make_train_step
from repro.models import layers as L
from repro.models.registry import get_model
from repro.optim.optimizer import init_state
from repro.data import pipeline

from repro.parallel.compat import auto_mesh
mesh = auto_mesh((2,4), ("data","model"))
minfo = L.MeshInfo.from_axes(("data","model"))
cfg = cfglib.get_smoke_config("qwen3-14b")
cell = ShapeCell("mini", 16, 8, "train")
tcfg = TrainConfig(microbatch_per_device=2, warmup_steps=2)
api = get_model(cfg)
specs = api.param_specs(cfg, minfo)
with mesh:
    params = jax.device_put(api.init(jax.random.PRNGKey(0), cfg, minfo),
                            L.shardings(mesh, specs))
    opt = init_state(params, tcfg)
    step_fn, n_micro, _ = make_train_step(cfg, tcfg, api, minfo, mesh, cell)
    jitted = jax.jit(step_fn, donate_argnums=(0,1))
    losses = []
    for step in range(4):
        batch = pipeline.shard_batch(
            pipeline.make_batch(cfg, cell, step), mesh, minfo)
        params, opt, _, m = jitted(params, opt, None, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    print("OK", losses[0], "->", losses[-1])
"""
    res = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
