"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED config (same family,
small dims) and runs one forward + one train step on CPU, asserting
output shapes and no NaNs. The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfglib
from repro.configs.base import ShapeCell, TrainConfig
from repro.data import pipeline
from repro.launch.train import make_train_step
from repro.models import layers as L
from repro.models.registry import get_model
from repro.optim.optimizer import init_state

CELL = ShapeCell("smoke", seq_len=16, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfglib.get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = pipeline.make_batch(cfg, CELL, step=0)

    logits = api.forward(params, cfg, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in forward"

    tcfg = TrainConfig(microbatch_per_device=2, warmup_steps=2)
    step_fn, _, _ = make_train_step(cfg, tcfg, api, L.HOST, None, CELL)
    opt = init_state(params, tcfg)
    params2, opt2, _, metrics = jax.jit(step_fn)(params, opt, None, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: bad grad norm"
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published hyperparameters."""
    cfg = cfglib.get_config(arch)
    expected = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_moe_assignment_details():
    v3 = cfglib.get_config("deepseek-v3-671b")
    assert (v3.num_experts, v3.experts_per_token, v3.num_shared_experts,
            v3.moe_d_ff) == (256, 8, 1, 2048)
    assert v3.use_mla
    scout = cfglib.get_config("llama4-scout-17b-a16e")
    assert (scout.num_experts, scout.experts_per_token) == (16, 1)


def test_ssm_assignment_details():
    z = cfglib.get_config("zamba2-7b")
    assert z.ssm_state == 64 and z.attn_every == 6
    r = cfglib.get_config("rwkv6-7b")
    assert r.family == "ssm" and r.rwkv_head_dim == 64


def test_param_counts_plausible():
    """Sanity: FULL-config param counts land near the advertised sizes."""
    from repro.launch.roofline import count_params

    for arch, low, high in [
        ("deepseek-7b", 6e9, 9e9),
        ("llama3-405b", 380e9, 430e9),
        ("deepseek-v3-671b", 600e9, 720e9),
        ("rwkv6-7b", 6e9, 9e9),
        ("whisper-medium", 0.5e9, 1.0e9),  # actual whisper-medium: 769M
    ]:
        cfg = cfglib.get_config(arch)
        api = get_model(cfg)
        total, _, _ = count_params(api.param_specs(cfg, L.HOST))
        assert low < total < high, f"{arch}: {total/1e9:.1f}B params"


def test_long500k_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    cells = dict()
    for arch, shape in cfglib.all_cells():
        cells.setdefault(arch, []).append(shape)
    assert "long_500k" in cells["zamba2-7b"]
    assert "long_500k" in cells["rwkv6-7b"]
    for arch in ("llama3-405b", "qwen3-14b", "whisper-medium",
                 "deepseek-v3-671b"):
        assert "long_500k" not in cells[arch]
    # every arch has the other three cells
    for arch, shapes in cells.items():
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
