"""Checkpoint manager: atomic, async, retention, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save(5, tree, meta={"config": "x"})
    restored, manifest = m.restore(5, tree)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        m.save(s, tree)
    assert m.all_steps() == [3, 4]
    assert m.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save_async(7, tree)
    m.wait()
    restored, _ = m.restore(7, tree)
    np.testing.assert_array_equal(restored["nested"]["b"], tree["nested"]["b"])


def test_meta_mismatch_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save(1, tree, meta={"config": "A"})
    with pytest.raises(ValueError, match="meta mismatch"):
        m.restore(1, tree, expect_meta={"config": "B"})


def test_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"a": jnp.ones((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        m.restore(1, {"a": jnp.ones((8, 4))})


def test_partial_write_is_invisible(tmp_path):
    """A crashed save (tmp dir left behind) must not count as a ckpt."""
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"a": jnp.ones(3)})
    os.makedirs(tmp_path / "step_0000000009")  # no manifest => incomplete
    assert m.all_steps() == [1]
    assert m.latest_step() == 1


def test_elastic_reshard_roundtrip(tmp_path):
    """Save replicated, restore under an explicit (1,1) mesh sharding —
    the elastic path (different mesh than saved) exercised end to end."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    m = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    m.save(3, tree)
    from repro.parallel.compat import auto_mesh

    mesh = auto_mesh((1, 1), ("data", "model"))
    shard = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = m.restore(3, tree, shardings=shard)
    assert restored["w"].sharding == shard["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
