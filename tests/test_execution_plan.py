"""ExecutionPlan / AutoPolicy: depth-aware planning with diagnostics.

The planner sweeps ring depth per layer under the sidebar-capacity
constraint, scores candidates with the EDP model, and returns the plan
*plus* diagnostics (instead of mutating policy state, which made policy
objects unshareable in PR 1).
"""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.core import (
    DEFAULT_TABLE,
    AutoPolicy,
    ExecutionMode,
    ExecutionPlan,
    FlexibleOp,
    LayerGraph,
    LayerPlan,
    PlanResult,
    StaticOp,
    account,
    estimate,
    plan,
)
from repro.core.sidebar import pipelined_capacity


def _mm(w, x):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def _graph(name="g", b=64, d=512, f=1024, d2=8, act="softplus"):
    return LayerGraph(
        name,
        ops=(
            StaticOp("w1", _mm, (b, f), flops=2 * b * d * f,
                     weight_bytes=d * f * 4),
            FlexibleOp(act, (b, f)),
            StaticOp("w2", _mm, (b, d2), flops=2 * b * f * d2,
                     weight_bytes=f * d2 * 4),
        ),
        in_shape=(b, d),
    )


def _static_only(name="s", b=8, d=32):
    return LayerGraph(
        name,
        ops=(StaticOp("w", _mm, (b, d), flops=2 * b * d * d,
                      weight_bytes=d * d * 4),),
        in_shape=(b, d),
    )


def test_layer_plan_validates_depth():
    with pytest.raises(ValueError, match="depth"):
        LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=0)


def test_execution_plan_uniform_and_lookup():
    p = ExecutionPlan.uniform("sidebar_pipelined", depth=4)
    assert p.default.mode is ExecutionMode.SIDEBAR_PIPELINED
    assert p.for_layer("anything").depth == 4
    override = ExecutionPlan(
        default=LayerPlan(ExecutionMode.SIDEBAR),
        layers={"hot": LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=8)},
    )
    assert override.for_layer("hot").depth == 8
    assert override.for_layer("cold").mode is ExecutionMode.SIDEBAR


def test_auto_policy_plan_returns_result_without_mutation():
    policy = AutoPolicy(table=DEFAULT_TABLE)
    graphs = [_graph("a"), _graph("b", act="relu"), _static_only("c")]
    result = policy.plan(graphs)
    assert isinstance(result, PlanResult)
    # stateless: the PR-1 mutable counter is gone and the policy is frozen
    assert not hasattr(policy, "fallbacks")
    with pytest.raises(dataclasses.FrozenInstanceError):
        policy.sidebar_capacity = 0
    assert set(result.plan.layers) == {"a", "b", "c"}
    assert result.for_layer("c").mode is ExecutionMode.MONOLITHIC
    for name in ("a", "b"):
        lp = result.for_layer(name)
        assert lp.mode in (ExecutionMode.SIDEBAR,
                           ExecutionMode.SIDEBAR_PIPELINED)
        assert result.diagnostics.edp[name] > 0
    assert result.diagnostics.fallbacks == ()
    # the plan default is the modal per-layer choice (what Server's
    # layer-agnostic trace applies), not a hardcoded constant
    assert result.plan.default in set(result.plan.layers.values())
    assert result.plan.default == max(
        result.plan.layers.values(),
        key=list(result.plan.layers.values()).count,
    )


def test_auto_policy_diagnoses_capacity_fallback():
    tiny = AutoPolicy(table=DEFAULT_TABLE, sidebar_capacity=1024)
    g = _graph("big")
    result = tiny.plan([g])
    assert result.for_layer("big").mode is ExecutionMode.FLEXIBLE_DMA
    assert result.diagnostics.fallbacks == ("big",)
    assert result.diagnostics.depth_sweep.get("big", {}) == {}


def test_auto_policy_depth_sweep_prefers_deeper_ring():
    """On the uneven graph the EDP model strictly prefers T=4 over T=2, so
    the sweep must surface and choose a deeper-than-2 ring."""
    policy = AutoPolicy(table=DEFAULT_TABLE)
    g = _graph("uneven")
    result = policy.plan([g])
    lp = result.for_layer("uneven")
    assert lp.mode is ExecutionMode.SIDEBAR_PIPELINED
    sweep = result.diagnostics.depth_sweep["uneven"]
    assert set(sweep) >= {2, 4}
    assert sweep[4] < sweep[2]
    assert lp.depth >= 4
    assert sweep[lp.depth] == min(sweep.values())
    assert result.diagnostics.edp["uneven"] == min(sweep.values())


def test_auto_policy_capacity_limits_depth():
    """A T-deep ring needs T slot pairs; when the lead axis doesn't divide
    by T the ceil-sized tiles make deeper rings strictly bigger. Shrink
    the sidebar so depth 8 no longer fits and the sweep must stop at a
    feasible depth."""
    g = _graph("cap", b=12)  # 12 % 8 != 0: depth 8 stages 8 x ceil(12/8)
    (_, op, shape), = [t for t in g.flexible_ops()]
    cap_for = lambda t: pipelined_capacity(shape, op.out_shape,
                                           g.itemsize, tiles=t)
    capacity = cap_for(4)  # depth 4 fits exactly; depth 8 does not
    assert cap_for(8) > capacity >= cap_for(4)
    policy = AutoPolicy(table=DEFAULT_TABLE, sidebar_capacity=capacity)
    result = policy.plan([g])
    sweep = result.diagnostics.depth_sweep["cap"]
    assert 8 not in sweep and 4 in sweep
    assert result.for_layer("cap").depth <= 4


def test_policy_callable_compatibility():
    policy = AutoPolicy(table=DEFAULT_TABLE)
    g = _graph("x")
    assert policy(g) is policy.plan([g]).for_layer("x").mode


def test_module_plan_wraps_plain_policies():
    from repro.core.policy import fixed

    graphs = [_graph("a"), _graph("b")]
    result = plan(graphs, fixed(ExecutionMode.SIDEBAR))
    assert isinstance(result, PlanResult)
    assert all(result.for_layer(g.name).mode is ExecutionMode.SIDEBAR
               for g in graphs)


def test_planned_depth_reduces_modeled_latency():
    """Threading the planned depth into account/estimate beats the PR-1
    fixed double buffer on the depth-sensitive graph."""
    g = _graph("z")
    policy = AutoPolicy(table=DEFAULT_TABLE)
    lp = policy.plan([g]).for_layer("z")
    assert lp.mode is ExecutionMode.SIDEBAR_PIPELINED and lp.depth > 2
    planned = estimate(account(g, lp, DEFAULT_TABLE))
    fixed_t2 = estimate(account(g, ExecutionMode.SIDEBAR_PIPELINED,
                                DEFAULT_TABLE, depth=2))
    assert planned.latency_s < fixed_t2.latency_s
    assert planned.edp < fixed_t2.edp
