"""Link-and-reference checker for the docs layer.

Docs rot in three characteristic ways, each checked here against the
tree as it actually is:

  links  — every relative markdown link in docs/*.md and README.md
           must resolve to a real file (anchors are stripped; absolute
           URLs are ignored);
  paths  — every repo path a doc names in prose or code spans
           (src/repro/..., tests/..., benchmarks/..., examples/...,
           tools/..., docs/...) must exist;
  flags  — every ``--flag`` a doc names must be a real flag of
           examples/serve_batch.py (parsed from its add_argument
           calls) or one of the few known non-argparse flags.

Run: python tools/check_docs.py          (from the repo root or not —
the repo root is located relative to this file). Exit 0 = docs clean;
1 = each violation printed with file and rule. CI runs this in the
lint job so a renamed module, a dropped flag, or a moved doc fails the
build instead of silently orphaning the docs.
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]

# [text](target) — markdown links, including images
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# repo-relative file references named in prose/code spans
PATH_RE = re.compile(
    r"\b(?:src/repro|tests|benchmarks|examples|tools|docs)"
    r"(?:/[\w.-]+)*/[\w.-]+\.(?:py|md|json|yml)\b"
)
FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9]*(?:-[a-z0-9]+)*\b")
ARG_RE = re.compile(r"add_argument\(\s*[\"'](--[a-z0-9-]+)[\"']")

# flags that are real but not serve_batch argparse flags
KNOWN_FLAGS = {
    "--json",    # benchmarks/run.py output switch (hand-parsed)
    "--check",   # ruff format --check (the CI lint invocation)
}


def serve_flags() -> set[str]:
    src = (REPO / "examples" / "serve_batch.py").read_text()
    return set(ARG_RE.findall(src)) | KNOWN_FLAGS


def check_file(path: pathlib.Path, flags: set[str]) -> list[str]:
    errors = []
    rel = path.relative_to(REPO)
    text = path.read_text()

    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        plain = target.split("#", 1)[0]
        if not plain:        # pure in-page anchor
            continue
        if not (path.parent / plain).exists():
            errors.append(f"{rel}: broken link -> {target}")

    for ref in sorted(set(PATH_RE.findall(text))):
        if not (REPO / ref).exists():
            errors.append(f"{rel}: referenced path does not exist: {ref}")

    for flag in sorted(set(FLAG_RE.findall(text))):
        if flag not in flags:
            errors.append(
                f"{rel}: names flag {flag}, which examples/"
                f"serve_batch.py does not define"
            )
    return errors


def main() -> int:
    flags = serve_flags()
    errors = []
    for path in DOC_FILES:
        if path.exists():
            errors.extend(check_file(path, flags))
        else:
            errors.append(f"expected doc file missing: "
                          f"{path.relative_to(REPO)}")
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        n = sum(1 for p in DOC_FILES if p.exists())
        print(f"check_docs: {n} files, all links/paths/flags resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
